"""Paper Sec. V-A simulation driver (Fig. 4/5/6 style experiments).

Synthetic CIFAR-stand-in (offline container — DESIGN.md §7), CNN model,
N clients with symmetric-Dirichlet heterogeneity, Rayleigh fading + AWGN.

  PYTHONPATH=src python examples/fl_cifar_sim.py \
      --policies fairk,topk,toprand --rounds 200 --dir 0.3 --rho 0.1
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.oac import ChannelConfig
from repro.data import partition, synthetic
from repro.fl import FLConfig, train
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--policies", default="fairk,topk,agetopk,toprand")
    ap.add_argument("--rounds", type=int, default=150)
    ap.add_argument("--clients", type=int, default=20)
    ap.add_argument("--dir", type=float, default=0.3, dest="dir_alpha")
    ap.add_argument("--rho", type=float, default=0.1)
    ap.add_argument("--km-frac", type=float, default=0.75)
    ap.add_argument("--local-steps", type=int, default=5)
    ap.add_argument("--noise", type=float, default=0.2)
    ap.add_argument("--model", choices=("mlp", "cnn"), default="cnn")
    ap.add_argument("--iid", action="store_true")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    spec = synthetic.DatasetSpec("cifar-like", (16, 16, 3), 10, 12000, 1500,
                                 noise_std=1.0, sparsity=0.08)
    (xtr, ytr), (xte, yte) = synthetic.make_dataset(spec, seed=0)
    if args.iid:
        parts = partition.iid_partition(len(ytr), args.clients, seed=0)
    else:
        parts = partition.dirichlet_partition(ytr, args.clients,
                                              args.dir_alpha, seed=0)
    key = jax.random.PRNGKey(0)
    if args.model == "cnn":
        params0 = cnn.init_prototype_cnn(key, (16, 16, 3), 10,
                                         widths=(12, 16, 24), fc_width=48)
        apply_fn = cnn.prototype_cnn
    else:
        params0 = cnn.init_mlp_classifier(key, 768, 10, hidden=(64,))
        apply_fn = cnn.mlp_classifier
    print(f"d = {cnn.param_count(params0)} params, N = {args.clients}, "
          f"Dir = {'iid' if args.iid else args.dir_alpha}, rho = {args.rho}")

    def loss_fn(p, x, y):
        return cnn.softmax_xent(apply_fn(p, x), y)

    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

    @jax.jit
    def eval_fn(p):
        return {"acc": cnn.accuracy(apply_fn(p, xte_j), yte_j)}

    def sample_round(t):
        return partition.client_batches(xtr, ytr, parts, 20,
                                        args.local_steps, seed=1000 + t)

    results = {}
    for policy in args.policies.split(","):
        fl = FLConfig(n_clients=args.clients, local_steps=args.local_steps,
                      batch_size=20, local_lr=0.05, global_lr=0.05,
                      rounds=args.rounds, policy=policy,
                      compression_ratio=args.rho, k_m_frac=args.km_frac,
                      channel=ChannelConfig(fading="rayleigh", mean=1.0,
                                            noise_std=args.noise))
        print(f"=== {policy}")
        h = train(fl, params0, loss_fn, sample_round, eval_fn=eval_fn,
                  eval_every=max(args.rounds // 6, 1), verbose=True)
        results[policy] = {"round": h["round"], "acc": h["acc"],
                           "mean_aou": h["mean_aou"],
                           "never_frac": float((h["sel_count"] == 0).mean())}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print("wrote", args.out)
    print("\nsummary:")
    for p, r in results.items():
        print(f"  {p:10s} acc={r['acc'][-1]:.3f} "
              f"meanAoU={np.mean(r['mean_aou'][args.rounds//2:]):.1f} "
              f"never={r['never_frac']*100:.0f}%")


if __name__ == "__main__":
    main()
