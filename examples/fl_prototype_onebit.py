"""Prototype demonstration (paper Sec. V-B): one-bit FSK majority-vote OAC.

The hardware prototype quantizes the selected gradient entries to signs,
transmits via FSK, and the server majority-votes — we simulate that digital
pipeline end-to-end with the paper's 109k-parameter CNN on the EMNIST-like
synthetic dataset at rho = 20%.

  PYTHONPATH=src python examples/fl_prototype_onebit.py --rounds 100
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.oac import ChannelConfig
from repro.data import partition, synthetic
from repro.fl import FLConfig, train
from repro.models import cnn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=100)
    ap.add_argument("--clients", type=int, default=2,
                    help="prototype uses N=2 SDR clients")
    ap.add_argument("--full-cnn", action="store_true",
                    help="use the full 28x28 EMNIST-like task + 109k CNN")
    args = ap.parse_args()

    if args.full_cnn:
        img, n_classes = (28, 28, 1), 26
        widths, fc = (24, 32, 48), 192        # d = 109,210 (paper: 109,402)
        n_train = 24_000
    else:
        img, n_classes = (16, 16, 1), 26
        widths, fc = (12, 16, 24), 64
        n_train = 8_000
    spec = synthetic.DatasetSpec("emnist-like", img, n_classes, n_train,
                                 2_000, noise_std=1.0, sparsity=0.1)
    (xtr, ytr), (xte, yte) = synthetic.make_dataset(spec, seed=0)
    parts = partition.dirichlet_partition(ytr, args.clients, 1.0, seed=0)
    params0 = cnn.init_prototype_cnn(jax.random.PRNGKey(0), img, n_classes,
                                     widths=widths, fc_width=fc)
    print(f"prototype CNN d = {cnn.param_count(params0)}, N = {args.clients} "
          f"clients, one-bit FSK-MV uplink, rho = 20%")

    def loss_fn(p, x, y):
        return cnn.softmax_xent(cnn.prototype_cnn(p, x), y)

    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

    @jax.jit
    def eval_fn(p):
        return {"acc": cnn.accuracy(cnn.prototype_cnn(p, xte_j), yte_j)}

    def sample_round(t):
        return partition.client_batches(xtr, ytr, parts, 32, 5, seed=t)

    for policy in ("fairk", "topk", "toprand"):
        fl = FLConfig(n_clients=args.clients, local_steps=5, batch_size=32,
                      local_lr=0.05, global_lr=0.003, rounds=args.rounds,
                      policy=policy, compression_ratio=0.2, one_bit=True,
                      channel=ChannelConfig(fading="none", mean=1.0,
                                            noise_std=1.0))
        h = train(fl, params0, loss_fn, sample_round, eval_fn=eval_fn,
                  eval_every=max(args.rounds // 4, 1))
        print(f"  {policy:10s} acc curve: "
              f"{['%.3f' % a for a in h['acc']]}")


if __name__ == "__main__":
    main()
