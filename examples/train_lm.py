"""End-to-end LM training driver: an assigned-architecture family variant
trained for a few hundred steps through the full production path — sharded
train step (FSDPxTP mesh over the host devices), OAC-FAIR-k server phase,
checkpointing, loss curve.

Default is a ~15M-parameter qwen-family variant sized for a CPU container;
``--size 100m`` builds a ~100M variant (same code path, longer wall-time).

  PYTHONPATH=src python examples/train_lm.py --steps 200 --arch qwen2.5-32b
"""

import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.configs import ARCHS, get_config
from repro.configs.base import InputShape
from repro.data.tokens import lm_batch
from repro.launch.steps import (OacServerConfig, init_server_state,
                                make_train_step)
from repro.models import transformer as tr
from repro.optim import make_optimizer


def sized_config(arch: str, size: str):
    cfg = get_config(arch, reduced_variant=True)
    if size == "100m":
        cfg = dataclasses.replace(
            cfg, name=cfg.name + "-100m", n_layers=8 * cfg.scan_block,
            d_model=512, n_heads=8 if cfg.n_heads else 0,
            n_kv_heads=2 if cfg.n_heads else 0,
            head_dim=64 if cfg.n_heads else 0,
            d_ff=2048 if cfg.d_ff else 0, vocab=32768)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2.5-32b")
    ap.add_argument("--size", choices=("small", "100m"), default="small")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rho", type=float, default=0.1)
    ap.add_argument("--noise", type=float, default=0.0,
                    help="channel noise sigma_z (scaled by 1/N_clients)")
    ap.add_argument("--no-oac", dest="oac", action="store_false",
                    default=True)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = sized_config(args.arch, args.size)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    shape = InputShape("custom", args.seq, args.batch, "train")
    oac = (OacServerConfig(rho=args.rho, noise_std=args.noise)
           if args.oac else None)
    bundle = make_train_step(cfg, shape, mesh, n_micro=1, oac=oac,
                             opt_name="adamw", lr=args.lr)

    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    opt = make_optimizer("adamw", args.lr)
    opt_state = opt.init(params)
    server = init_server_state(params, mesh=mesh, cfg=cfg, oac=oac)
    step_fn = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                      out_shardings=bundle.out_shardings)
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}, "
          f"OAC-FAIR-k {'on (rho=%.2f)' % args.rho if args.oac else 'off'}")

    t_start = time.time()
    with mesh:
        for t in range(args.steps):
            toks, labels = lm_batch(t, args.batch, args.seq, cfg.vocab)
            batch = {"tokens": jnp.asarray(toks)[None],
                     "labels": jnp.asarray(labels)[None]}
            if cfg.family == "vlm":
                batch["embeds"] = jnp.zeros(
                    (1, args.batch, cfg.n_patches, cfg.d_model),
                    jnp.dtype(cfg.compute_dtype))
                batch["tokens"] = batch["tokens"][:, :, :args.seq
                                                  - cfg.n_patches]
                batch["labels"] = batch["labels"][:, :, :args.seq
                                                  - cfg.n_patches]
            if cfg.family == "audio":
                batch["frames"] = jnp.zeros(
                    (1, args.batch, cfg.encoder_seq, cfg.d_model),
                    jnp.dtype(cfg.compute_dtype))
            params, opt_state, server, loss = step_fn(
                params, opt_state, server, batch,
                jnp.asarray(t, jnp.int32))
            if t % 10 == 0 or t == args.steps - 1:
                print(f"  step {t:4d}  loss {float(loss):.4f}  "
                      f"({(time.time()-t_start)/(t+1):.2f}s/step)",
                      flush=True)
            if args.ckpt_dir and (t + 1) % 50 == 0:
                checkpoint.save(args.ckpt_dir, jax.device_get(params),
                                step=t + 1)
    if args.ckpt_dir:
        path = checkpoint.save(args.ckpt_dir, jax.device_get(params),
                               step=args.steps)
        print(f"[train_lm] final checkpoint: {path}")
    print("[train_lm] done")


if __name__ == "__main__":
    main()
