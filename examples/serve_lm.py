"""Batched serving example: prefill a batch of prompts through the sharded
production path, then greedy-decode new tokens step by step from the KV /
SSM caches.

  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m --tokens 32
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.configs.base import InputShape
from repro.data.tokens import lm_batch
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2.5-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced_variant=True)
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((1, n_dev), ("data", "model"))
    capacity = args.prompt_len + args.tokens + (cfg.n_patches or 0)
    params = tr.init_lm(jax.random.PRNGKey(0), cfg)
    prompts, _ = lm_batch(0, args.batch, args.prompt_len, cfg.vocab)
    prompts = jnp.asarray(prompts)

    serve_shape = InputShape("serve", capacity, args.batch, "decode")
    serve = make_serve_step(cfg, serve_shape, mesh)
    step_fn = jax.jit(serve.fn, in_shardings=serve.in_shardings,
                      out_shardings=serve.out_shardings)

    with mesh:
        caches = tr.init_caches(cfg, args.batch, capacity)
        t0 = time.time()
        logits, caches = tr.prefill(params, cfg, prompts, caches)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        print(f"[serve_lm] {cfg.name}: prefill {args.batch}x"
              f"{args.prompt_len} in {time.time()-t0:.2f}s")
        out_tokens = [tok]
        t0 = time.time()
        for i in range(args.tokens - 1):
            pos = jnp.asarray(args.prompt_len + i, jnp.int32)
            logits, caches = step_fn(params, caches, tok, pos)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        dt = time.time() - t0
        gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve_lm] decoded {args.tokens} tokens/seq in {dt:.2f}s "
          f"({args.batch*args.tokens/dt:.1f} tok/s aggregate)")
    for b in range(min(args.batch, 2)):
        print(f"  seq {b}: {gen[b][:16].tolist()} ...")


if __name__ == "__main__":
    main()
